"""Pipeline parallelism over the heterogeneous mesh (ISSUE 7).

Contracts:

* the flattened wavefront engine is bit-exact against the unpipelined
  task-major reference (EFT and stage-FlexAI policies);
* a 1-stage pipeline with the task-level policy IS the existing scan
  engine (bit-exact state and records);
* the stage-share decomposition is honest: per-stage exec times sum back
  to the whole-model exec table (no accelerator gets faster in aggregate);
* route batches padded to a lane multiple (``pad_route_batch``) change
  nothing for the real lanes;
* a wavefront segment split at any chunk cut resumes bit-exactly from the
  ``(state, ring)`` checkpoint — the QoS preemption contract;
* QoS pipeline waves (``cfg.stages > 1``) serve real stage placements:
  a solo request reproduces the direct pipeline schedule, and preemption/
  resume does not change any placement;
* stage-level FlexAI trains end-to-end on the scan path and, on a
  single-stage workload, is no worse than the task-level agent;
* (slow) the shard_map'd engine on a (2, 2) ``("stages", "routes")`` mesh
  reproduces the flattened engine bit-exactly, ring hops via ppermute.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.flexai import FlexAIAgent, FlexAIConfig, ScanFlexAI
from repro.core.flexai.engine import make_schedule_fn
from repro.core.hmai import HMAIPlatform
from repro.core.pipeline import (PipelineFlexAI, build_stage_plan,
                                 make_pipeline_reference_fn,
                                 make_pipeline_schedule_fn,
                                 _pipeline_segment_run, _wavefront_stream)
from repro.core.platform_jax import spec_from_platform
from repro.core.tasks import (pad_route_batch, pad_task_arrays,
                              stack_task_arrays, tasks_to_arrays)

RS = 0.05


def _queue(seed, km=0.02):
    return build_task_queue(EnvironmentParams(
        route_km=km, rate_scale=RS, seed=seed, max_times_turn=2,
        max_times_reverse=1, max_duration_turn=4.0,
        max_duration_reverse=6.0))


def _platform():
    return HMAIPlatform(capacity_scale=RS)


def _cfg(**over):
    kw = dict(min_replay=32, batch_size=16, update_every=2,
              eps_decay_steps=500, replay_capacity=2048, seed=2)
    kw.update(over)
    return FlexAIConfig(**kw)


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# share-model honesty
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stages", [2, 3])
def test_stage_exec_decomposes_exec_table(stages):
    """Per-stage exec (and energy) must sum back to the whole-model
    tables: splitting a model into stages redistributes work, it never
    makes an accelerator faster in aggregate."""
    plat = _platform()
    spec = spec_from_platform(plat)
    plan = build_stage_plan(plat, stages)
    np.testing.assert_allclose(
        np.asarray(plan.stage_exec).sum(0), np.asarray(spec.exec_time),
        rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(plan.stage_energy).sum(0), np.asarray(spec.energy),
        rtol=1e-5)
    # every accelerator belongs to exactly one group; every stage has one
    groups = np.asarray(plan.groups)
    assert set(groups.tolist()) == set(range(stages))
    mask = np.asarray(plan.group_mask)
    np.testing.assert_array_equal(mask, np.arange(stages)[:, None] == groups)


# ---------------------------------------------------------------------------
# engine parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["eft", "flexai"])
def test_flattened_matches_reference(policy):
    plat = _platform()
    spec = spec_from_platform(plat)
    plan = build_stage_plan(plat, 2)
    params = (None if policy == "eft"
              else PipelineFlexAI(plat, _cfg(), n_stages=2).eval_params())
    ta = tasks_to_arrays(_queue(31))
    flat = make_pipeline_schedule_fn(spec, plan, policy=policy)
    ref = make_pipeline_reference_fn(spec, plan, policy=policy)
    assert _trees_equal(flat(params, ta), ref(params, ta))


def test_one_stage_task_policy_is_the_scan_engine():
    """S=1 pipeline with the task-level policy == make_schedule_fn:
    identical final state, and the [T, 1] stage records squeeze to the
    scan engine's [T] records."""
    plat = _platform()
    spec = spec_from_platform(plat)
    plan = build_stage_plan(plat, 1)
    params = FlexAIAgent(plat, _cfg()).learner.eval_p
    ta = tasks_to_arrays(_queue(32))
    f_p, _, r_p = make_pipeline_schedule_fn(spec, plan,
                                            policy="task")(params, ta)
    f_s, r_s = make_schedule_fn(spec)(params, ta)
    assert _trees_equal(f_p, f_s)
    assert _trees_equal(
        jax.tree_util.tree_map(lambda a: a[:, 0], r_p), r_s)


def test_padded_route_batch_is_inert():
    """pad_route_batch to a lane multiple: real lanes unchanged, padding
    lanes record nothing."""
    plat = _platform()
    spec = spec_from_platform(plat)
    plan = build_stage_plan(plat, 2)
    routes = [tasks_to_arrays(_queue(s)) for s in (33, 34, 35)]
    batch = pad_route_batch(stack_task_arrays(routes), 4)
    assert batch.arrival.shape[0] == 4
    fn = make_pipeline_schedule_fn(spec, plan, policy="eft", batched=True)
    fB, _, rB = fn(None, batch)
    T = batch.arrival.shape[1]
    solo = make_pipeline_schedule_fn(spec, plan, policy="eft")
    for lane, r in enumerate(routes):
        fL, _, rL = solo(None, pad_task_arrays(r, T))
        assert _trees_equal(
            jax.tree_util.tree_map(lambda a, l=lane: a[l], (fB, rB)),
            (fL, rL))
    assert not np.asarray(rB.valid)[3].any()


def test_segment_resume_bit_exact():
    """Splitting the flat wavefront at a segment cut and resuming from the
    (state, ring) checkpoint reproduces the single-pass run bit-exactly —
    the QoS preemption/resume contract."""
    plat = _platform()
    spec = spec_from_platform(plat)
    plan = build_stage_plan(plat, 2)
    params = PipelineFlexAI(plat, _cfg(), n_stages=2).eval_params()
    ta = tasks_to_arrays(_queue(36))
    rows, s_seq = _wavefront_stream(ta, 2)
    run = jax.jit(_pipeline_segment_run(spec, plan))
    f1, ring1, r1 = run(params, rows, s_seq)
    cut = 2 * (rows.arrival.shape[0] // 5)
    sl = lambda t, a, b: jax.tree_util.tree_map(  # noqa: E731
        lambda x: x[a:b], t)
    fa, ra, rec_a = run(params, sl(rows, 0, cut), s_seq[:cut])
    fb, rb, rec_b = run(params, sl(rows, cut, None), s_seq[cut:], fa, ra)
    assert _trees_equal(f1, fb)
    assert _trees_equal(ring1, rb)
    joined = jax.tree_util.tree_map(
        lambda a, b: np.concatenate([np.asarray(a), np.asarray(b)]),
        rec_a, rec_b)
    assert _trees_equal(r1, joined)


# ---------------------------------------------------------------------------
# QoS pipeline waves
# ---------------------------------------------------------------------------

def _stage_agent(plat):
    return PipelineFlexAI(plat, _cfg(), n_stages=2)


def test_qos_pipeline_wave_matches_direct_schedule():
    """A solo request through stages=2 QoS serving reproduces the direct
    flattened pipeline schedule of the same (bucket-padded) route."""
    from repro.serve.qos import QoSConfig, QoSPlacementEngine
    plat = _platform()
    pipe = _stage_agent(plat)
    q = _queue(37)
    cfg = QoSConfig(policy="edf", stages=2, slots=2, min_bucket=16)
    eng = QoSPlacementEngine(plat, pipe.eval_params(), cfg,
                             backlog_scale=pipe.cfg.backlog_scale)
    req = eng.submit(q)
    eng.run_until_done()
    assert req.status == "completed"
    assert req.summary["stages"] == 2
    assert req.summary["placements"].shape == (len(q), 2)
    direct = pipe.schedule(pad_task_arrays(tasks_to_arrays(q), req.bucket))
    np.testing.assert_array_equal(req.summary["placements"],
                                  direct["placements"][: len(q)])
    assert req.summary["stm_rate"] == pytest.approx(direct["stm_rate"],
                                                    abs=1e-9)


def test_qos_pipeline_preemption_does_not_change_placements():
    """Pipeline waves preempt at flat segment cuts with a (state, ring)
    checkpoint; placements must be identical with preemption on or off."""
    from repro.serve.qos import QoSConfig, QoSPlacementEngine
    plat = _platform()
    pipe = _stage_agent(plat)
    routes = [_queue(38, km=0.03), _queue(39), _queue(40)]

    def serve(preempt):
        cfg = QoSConfig(policy="edf", stages=2, slots=1, min_bucket=16,
                        preempt=preempt, laxity_s=1e-4, shed=False)
        eng = QoSPlacementEngine(plat, pipe.eval_params(), cfg,
                                 backlog_scale=pipe.cfg.backlog_scale)
        # the long route starts first with a slack deadline; tighter
        # routes arrive mid-wave and must preempt it at a segment cut
        eng.submit(routes[0], arrival=0.0, deadline=1e6)
        eng.submit(routes[1], arrival=1e-4, deadline=0.05)
        eng.submit(routes[2], arrival=2e-4, deadline=0.06)
        eng.run_until_done()
        return eng

    on, off = serve(True), serve(False)
    assert on.preemption_count > 0
    by_uid = {r.uid: r for r in off.completed}
    assert len(on.completed) == len(routes)
    for r in on.completed:
        np.testing.assert_array_equal(r.summary["placements"],
                                      by_uid[r.uid].summary["placements"])


def test_durability_rejects_pipeline_waves():
    from repro.serve.durability import DurableQoSEngine
    from repro.serve.qos import QoSConfig
    plat = _platform()
    pipe = _stage_agent(plat)
    with pytest.raises(ValueError, match="pipeline"):
        DurableQoSEngine(plat, pipe.eval_params(),
                         QoSConfig(stages=2))


# ---------------------------------------------------------------------------
# stage-level FlexAI training
# ---------------------------------------------------------------------------

def test_stage_flexai_trains_and_matches_task_agent_on_one_stage():
    """The stage agent must learn end-to-end on the scan path (updates
    fire, losses recorded), and with a single stage — where placement is
    the same problem the task agent solves — its scheduled STM must be no
    worse (small tolerance; the two nets see different state encodings)."""
    plat = _platform()
    queues = [_queue(41), _queue(42)]
    eval_q = _queue(43)
    cfg = _cfg(update_every=1, eps_decay_steps=300)

    pipe1 = PipelineFlexAI(plat, cfg, n_stages=1)
    pipe1.train(queues, episodes=30, eval_queue=eval_q, eval_every=3)
    assert len(pipe1.losses) > 0
    stage_stm = pipe1.schedule(eval_q)["stm_rate"]

    task = ScanFlexAI(plat, cfg)
    task.train(queues, episodes=30, eval_queue=eval_q, eval_every=3)
    task_stm = task.schedule(eval_q)["stm_rate"]
    assert stage_stm >= task_stm - 0.05

    # and the 2-stage agent trains on the same pool
    pipe2 = PipelineFlexAI(plat, cfg, n_stages=2)
    hist = pipe2.train(queues, episodes=4)
    assert len(pipe2.losses) > 0
    assert all(h["stages"] == 2 for h in hist)


# ---------------------------------------------------------------------------
# sharded engine (subprocess: forced host devices before jax imports)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_pipeline_matches_flattened():
    script = textwrap.dedent("""
        import jax
        import numpy as np
        from repro.core.environment import EnvironmentParams, \\
            build_task_queue
        from repro.core.hmai import HMAIPlatform
        from repro.core.pipeline import (build_stage_plan,
                                         combine_stage_states,
                                         make_pipeline_schedule_fn,
                                         make_sharded_pipeline_fn)
        from repro.core.platform_jax import spec_from_platform
        from repro.core.tasks import stack_task_arrays, tasks_to_arrays
        from repro.launch.mesh import make_platform_mesh

        RS = 0.05
        def queue(seed):
            return build_task_queue(EnvironmentParams(
                route_km=0.02, rate_scale=RS, seed=seed, max_times_turn=2,
                max_times_reverse=1, max_duration_turn=4.0,
                max_duration_reverse=6.0))
        plat = HMAIPlatform(capacity_scale=RS)
        spec = spec_from_platform(plat)
        plan = build_stage_plan(plat, 2)
        batch = stack_task_arrays(
            [tasks_to_arrays(queue(s)) for s in (44, 45)])
        mesh = make_platform_mesh(2)
        assert dict(zip(mesh.axis_names, mesh.devices.shape)) == \\
            {"stages": 2, "routes": 2}
        f_fl, _, r_fl = make_pipeline_schedule_fn(
            spec, plan, policy="eft", batched=True)(None, batch)
        st, _, rc = make_sharded_pipeline_fn(
            spec, plan, mesh, policy="eft")(None, batch)
        for a, b in zip(jax.tree_util.tree_leaves(rc),
                        jax.tree_util.tree_leaves(r_fl)):
            assert np.array_equal(np.asarray(a).transpose(1, 2, 0),
                                  np.asarray(b))
        comb = combine_stage_states(plan, st)
        for a, b in zip(jax.tree_util.tree_leaves(comb),
                        jax.tree_util.tree_leaves(f_fl)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("OK", int(np.asarray(batch.valid).sum()))
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
