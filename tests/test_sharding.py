"""Sharding layer: logical-axis resolution (in-process) + an 8-device
subprocess check that a sharded train step runs and matches single-device
results (the dry-run proper covers the 512-device meshes)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.sharding import (DEFAULT_RULES, Param, abstract_mesh, boxed_axes,
                            logical_to_mesh_axes, unbox)


def test_param_boxing_roundtrip():
    import jax.numpy as jnp
    p = {"a": Param(jnp.ones((4, 8)), ("embed", "mlp")),
         "b": {"c": Param(jnp.zeros((3,)), ("unsharded",))}}
    values = unbox(p)
    axes = boxed_axes(p)
    assert values["a"].shape == (4, 8)
    assert axes["a"] == ("embed", "mlp")
    assert axes["b"]["c"] == ("unsharded",)


def test_eval_shape_keeps_boxes():
    import jax.numpy as jnp

    def init():
        return {"w": Param(jnp.zeros((8, 16)), ("embed", "mlp"))}

    shapes = jax.eval_shape(init)
    assert isinstance(shapes["w"], Param)
    assert shapes["w"].value.shape == (8, 16)
    assert shapes["w"].axes == ("embed", "mlp")


def test_multipod_axis_resolution():
    mesh = abstract_mesh((2, 4, 4), ("pod", "data", "model"))
    spec = logical_to_mesh_axes(("batch", None, "mlp"), DEFAULT_RULES, mesh)
    assert spec[0] == ("pod", "data")
    assert spec[2] == "model"
    # single-pod mesh: the "pod" component is dropped transparently
    mesh1 = abstract_mesh((4, 4), ("data", "model"))
    spec1 = logical_to_mesh_axes(("batch", None, "mlp"), DEFAULT_RULES, mesh1)
    assert spec1[0] == "data"


_SUBPROCESS_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.launch.mesh import make_test_mesh
    from repro.models.api import model_api
    from repro.sharding import activate, tree_shardings, unbox, Param
    from repro.train.loop import TrainHyper, init_train_state, make_train_step, train_state_boxed

    cfg = get_smoke_config("h2o-danube-3-4b")
    api = model_api(cfg)
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
        "loss_mask": jnp.ones((8, 32), jnp.float32),
    }
    hyper = TrainHyper(warmup_steps=1, total_steps=10)
    step = make_train_step(api, hyper)

    # single device
    params = unbox(api.init(key))
    state = init_train_state(params, hyper)
    _, m1 = jax.jit(step)(state, batch)
    loss1 = float(m1["loss"])

    # 2x4 mesh, sharded state
    mesh = make_test_mesh((2, 4), ("data", "model"))
    boxed = jax.eval_shape(api.init, key)
    boxed_state = train_state_boxed(boxed, hyper)
    shardings = tree_shardings(boxed_state, mesh)
    with activate(mesh):
        params2 = unbox(api.init(key))
        state2 = init_train_state(params2, hyper)
        state2 = jax.device_put(state2, shardings)
        jitted = jax.jit(step, in_shardings=(shardings, None))
        new_state, m2 = jitted(state2, batch)
        loss2 = float(m2["loss"])
    print(json.dumps({"loss1": loss1, "loss2": loss2}))
""")


def test_sharded_step_matches_single_device(tmp_path):
    script = tmp_path / "sharded_check.py"
    script.write_text(_SUBPROCESS_SCRIPT)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, str(script)], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    np.testing.assert_allclose(res["loss1"], res["loss2"], rtol=2e-2)
