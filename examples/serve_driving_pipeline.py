"""End-to-end driver (the paper's kind: SERVING): driving environment ->
camera task queue -> FlexAI scheduling -> heterogeneous virtual-accelerator
pools actually executing the perception CNNs with batched requests.

    PYTHONPATH=src python examples/serve_driving_pipeline.py

This is the TPU adaptation of Fig 5's data path: cameras -> per-camera
buffers -> RL scheduling strategy -> per-accelerator execution, with the
accelerators realized as device pools running reduced-width YOLO/SSD/GOTURN
and advertising *measured* rates (see repro/core/virtual_platform.py).
"""
import time

import numpy as np

from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.flexai import FlexAIAgent, FlexAIConfig
from repro.core.schedulers import get_scheduler
from repro.core.virtual_platform import VirtualPlatform

print("calibrating virtual accelerator pools (compiling perception CNNs)...")
t0 = time.time()
plat = VirtualPlatform(run_real=True)
for pool in plat.pools:
    print(f"  pool {pool.spec.name} [{pool.spec.archetype}]: "
          + ", ".join(f"{k}={v:.0f} fps" for k, v in
                      pool.measured_fps.items()))
print(f"calibration took {time.time()-t0:.1f}s")

# scale the camera rates to the measured pool capacity
cap = sum(np.mean(list(p.measured_fps.values())) for p in plat.pools)
rate_scale = min(1.0, cap / 1800.0)
print(f"aggregate capacity ~{cap:.0f} fps -> rate_scale={rate_scale:.4f}")

queue = build_task_queue(EnvironmentParams(route_km=0.02,
                                           rate_scale=rate_scale, seed=0))[:400]
print(f"task queue: {len(queue)} tasks")

# quick FlexAI training on the measured platform (simulated execution),
# then run the real pipeline
sim = VirtualPlatform(run_real=False)
agent = FlexAIAgent(sim, FlexAIConfig(min_replay=64, eps_decay_steps=3000,
                                      update_every=4))
agent.train(sim, [queue], episodes=2)

print("running the real pipeline (frames actually execute on pools)...")
plat.reset()
t0 = time.time()
summary = agent.schedule(plat, queue)
wall = time.time() - t0
print(f"FlexAI:   STM={summary['stm_rate']:.2f} "
      f"R_Balance={summary['r_balance']:.2f} wall={wall:.1f}s")

plat.reset()
summary = get_scheduler("worst").schedule(plat, queue)
print(f"worst:    STM={summary['stm_rate']:.2f} "
      f"R_Balance={summary['r_balance']:.2f}")
