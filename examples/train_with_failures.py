"""Fault-tolerant training demo: train a ~1M-param LM, kill it mid-run,
restart from the checkpoint, and verify the final state matches an
uninterrupted run (deterministic step-indexed data pipeline).

    PYTHONPATH=src python examples/train_with_failures.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.sharding import unbox
from repro.train.data import DataConfig, batch_fn
from repro.train.fault_tolerance import elastic_restore, run_with_fault_tolerance
from repro.train.loop import TrainHyper, init_train_state, make_train_step

cfg = ModelConfig(name="ft-demo", family="dense", num_layers=2, d_model=96,
                  num_heads=4, num_kv_heads=2, d_ff=192, vocab_size=256,
                  attention_impl="naive")
api = model_api(cfg)
hyper = TrainHyper(peak_lr=3e-3, warmup_steps=5, total_steps=60)
bat = batch_fn(cfg, DataConfig(batch_size=4, seq_len=32))
step = jax.jit(make_train_step(api, hyper))


def fresh_state():
    return init_train_state(unbox(api.init(jax.random.PRNGKey(0))), hyper)


tmp = tempfile.mkdtemp()
try:
    # uninterrupted reference
    ref = run_with_fault_tolerance(step, fresh_state(), bat, num_steps=60,
                                   ckpt_dir=tmp + "/ref", ckpt_every=20)
    print("reference run complete")

    # crash at step 37
    try:
        run_with_fault_tolerance(step, fresh_state(), bat, num_steps=60,
                                 ckpt_dir=tmp + "/crash", ckpt_every=20,
                                 fail_at_step=37)
    except RuntimeError as e:
        print(f"simulated failure: {e}")

    restored, start = elastic_restore(tmp + "/crash",
                                      jax.device_get(fresh_state()))
    print(f"restored from step {start}; resuming...")
    res = run_with_fault_tolerance(step, restored, bat, num_steps=60,
                                   ckpt_dir=tmp + "/crash", ckpt_every=20,
                                   start_step=start)

    ok = all(np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
             for a, b in zip(
                 jax.tree_util.tree_leaves(ref.final_state.params),
                 jax.tree_util.tree_leaves(res.final_state.params)))
    print(f"restart == uninterrupted: {ok}")
finally:
    shutil.rmtree(tmp, ignore_errors=True)
