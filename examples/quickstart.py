"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py

1. builds a tiny decoder LM, trains it a few steps on the synthetic stream,
2. serves greedy completions,
3. schedules a driving-automation task queue with FlexAI on simulated HMAI.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import model_api
from repro.models.config import ModelConfig
from repro.serve.engine import Request, ServeEngine
from repro.sharding import unbox
from repro.train.data import DataConfig, batch_fn
from repro.train.loop import TrainHyper, init_train_state, make_train_step

# ---- 1. train a tiny LM ---------------------------------------------------
cfg = ModelConfig(name="quickstart", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  attention_impl="naive")
api = model_api(cfg)
hyper = TrainHyper(peak_lr=3e-3, warmup_steps=5, total_steps=60)
state = init_train_state(unbox(api.init(jax.random.PRNGKey(0))), hyper)
step = jax.jit(make_train_step(api, hyper))
bat = batch_fn(cfg, DataConfig(batch_size=4, seq_len=32))
for i in range(60):
    state, metrics = step(state, bat(i))
    if i % 20 == 0:
        print(f"train step {i}: loss={float(metrics['loss']):.3f}")

# ---- 2. serve it ----------------------------------------------------------
eng = ServeEngine(api, state.params, slots=2, max_seq=48)
eng.submit(Request(uid=0, prompt=np.array([5, 12, 19], np.int32),
                   max_new_tokens=8))
eng.run_until_done()
print("generated:", eng.finished[0].generated)

# ---- 3. FlexAI on the simulated HMAI --------------------------------------
from repro.core.environment import EnvironmentParams, build_task_queue
from repro.core.flexai import FlexAIAgent, FlexAIConfig
from repro.core.hmai import HMAIPlatform

RS = 0.05
queue = build_task_queue(EnvironmentParams(route_km=0.05, rate_scale=RS))
plat = HMAIPlatform(capacity_scale=RS)
agent = FlexAIAgent(plat, FlexAIConfig(min_replay=64, eps_decay_steps=4000))
agent.train(plat, [queue], episodes=3)
plat.reset()
summary = agent.schedule(plat, queue)
print(f"FlexAI on {summary['tasks']} tasks: "
      f"STM rate={summary['stm_rate']:.2f}, "
      f"R_Balance={summary['r_balance']:.2f}")
