#!/usr/bin/env bash
# CI entry point.
#
# 1. Installs the optional dev deps (hypothesis) so tests/test_property.py
#    actually runs instead of importorskip-ing away; the install is
#    best-effort so air-gapped environments still get the rest of CI.
# 2. Runs the FULL tier-1 suite (no -x): since the PR-2 compat shim the
#    kernel, sharding and distribution suites pass on CPU jax 0.4.37, so
#    every failure gates.
# 3. Scan-engine parity gate on 2 forced host devices.
# 4. Sharded-engine smoke on 8 forced host devices: the shard_map'd
#    multi-device schedule path must match the single-device scan engine
#    (the child asserts fp32 parity before printing its result line).
# 5. DP-trainer parity gate on 8 forced host devices: the shard_map'd
#    data-parallel trainer must walk the same trajectory as the
#    unsharded DP runner (the child asserts placement/param parity
#    before printing its result line).
# 6. Quick-mode benchmark smoke: the metaheuristic throughput module
#    (device GA/SA vs the NumPy loop + fitness parity) must run end to
#    end and report fitness parity vs the oracle, and the training
#    throughput module (loop vs fused vs DP) must report loss/eval
#    parity across all three trainers.
# 7. Serving-QoS gate: the property suite (hypothesis when installed,
#    fixed-seed sweep otherwise, bounded example budget) plus the
#    BENCH_serving.json contract — EDF-with-aging must never miss more
#    deadlines than bucket-FIFO and must be strictly better overloaded.
# 8. Pipeline gate (BENCH_pipeline.json): stage-grouped EFT placement
#    over >= 2 accelerator groups must beat single-stage placement on
#    drain-workload makespan at equal device count, with the flattened
#    wavefront bit-exact vs the task-major reference and the (2,2)-mesh
#    shard_map run bit-exact vs the flattened engine.
# 9. Durability gate: the full durability suite incl. the slow
#    subprocess tests (SIGKILL mid-wave -> restore -> bit-exact digest;
#    elastic resume onto a 2-device mesh), then the recovery benchmark
#    smoke gating on BENCH_recovery.json — crash-recovery parity exact,
#    snapshot sync overhead < 10%, and graceful degradation strictly
#    better than the same fault unhandled.
# 10. Scenario-fleet gate (BENCH_scenarios.json): over the
#    domain-randomized scenario families, the degradation-trained /
#    health-aware FlexAI arm must have strictly lower deadline-miss than
#    the fault-blind clean-trained arm on the faulted routes while
#    staying within 2% STM of it on the clean routes.
# 11. Kernel suite + kernel honesty gate (BENCH_kernels.json): the full
#    kernel test suite in interpret mode (always), the same suite
#    compiled when a TPU/GPU accelerator is present (an explicit SKIPPED
#    line otherwise — never silently green), then the kernels benchmark:
#    interpret parity for every kernel family, the 64-update fused
#    TD-update trajectory pin (<= 1e-5), and the CPU-trainer structural
#    no-regression (default path pallas-free, td_kernel=False trace
#    identical to the default).
# 12. Open-loop load gate (BENCH_load.json, 2 forced host devices):
#    continuous-batching EDF must beat drain-wave EDF on goodput at
#    offered load 2.0 with no p99 latency regression at load 0.5, and
#    sharded waves must reproduce the single-device serving digest
#    bit-exactly on the parity trace (drain and continuous modes).
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

# XLA host tuning (recorded in each BENCH_*.json via benchmarks.common):
# step markers placed at entry so profiling never splits a fused scan;
# tcmalloc preloaded when the host ships it (allocator contention on
# many-core hosts).  Forced device counts are appended per-gate below and
# win because the last flag takes precedence inside XLA_FLAGS.
export XLA_FLAGS="${XLA_FLAGS:-} --xla_step_marker_location=STEP_MARK_AT_ENTRY"
TCMALLOC="$(ls /usr/lib/x86_64-linux-gnu/libtcmalloc*.so* \
    /usr/lib/libtcmalloc*.so* /usr/local/lib/libtcmalloc*.so* \
    2>/dev/null | head -n 1 || true)"
if [ -n "${TCMALLOC}" ]; then
    export LD_PRELOAD="${TCMALLOC}${LD_PRELOAD:+:${LD_PRELOAD}}"
    echo "host tuning: tcmalloc preloaded (${TCMALLOC})"
else
    echo "host tuning: no tcmalloc on this host (recorded as absent)"
fi

echo "== dev deps (hypothesis; best-effort) =="
python -m pip install -q -r requirements-dev.txt \
    || echo "pip install failed; property tests fall back to seeded sweeps"

echo "== tier-1 suite (full run incl. slow subprocess tests, gating) =="
# the serving property and durability suites are excluded here: each
# runs once in its own dedicated gate below
python -m pytest -q --runslow --ignore=tests/test_serve_properties.py \
    --ignore=tests/test_durability.py
tier1=$?

echo "== serving property contract (bounded example budget) =="
SERVE_QOS_EXAMPLES=20 python -m pytest -q tests/test_serve_properties.py
serve_prop=$?

echo "== serving QoS smoke (EDF vs FIFO at 3 loads) =="
python -m benchmarks.run --only serve_qos \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_serving.json"))
ok = r["edf_never_worse"] and r["edf_strictly_better_at_high_load"]
top = max(r["loads"], key=float)
print(f"edf_never_worse={r['edf_never_worse']} "
      f"strict_at_load_{top}={r['edf_strictly_better_at_high_load']} "
      f"(edf {r['loads'][top]['edf']['miss_rate']:.3f} vs "
      f"fifo {r['loads'][top]['fifo']['miss_rate']:.3f})")
sys.exit(0 if ok else 1)
EOF
serve_bench=$?

echo "== open-loop load gate (continuous vs drain, sharded parity; 2 devices) =="
# forced 2 host devices so the sharded-wave parity trace actually splits
# lanes across devices (slots=3 also exercises the pad-and-trim path);
# the gate itself stays seeded/deterministic on the virtual clock
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=2" \
    python -m benchmarks.run --only serve_load \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_load.json"))
g = r["gate"]
ok = (g["continuous_goodput_wins_overload"]
      and g["no_p99_regression_underload"] and g["sharded_parity"])
top = max(r["loads"], key=float)
arms = r["loads"][top]
print(f"goodput@load{top}: continuous "
      f"{arms['continuous']['goodput_rps']:.2f}/s vs drain "
      f"{arms['drain']['goodput_rps']:.2f}/s "
      f"p99_ok={g['no_p99_regression_underload']} "
      f"sharded_parity={g['sharded_parity']} "
      f"(devices={r['sharded_parity_devices']})")
sys.exit(0 if ok else 1)
EOF
serve_load=$?

echo "== durability suite (incl. SIGKILL recovery + elastic resume) =="
python -m pytest -q --runslow tests/test_durability.py
durability=$?

echo "== recovery benchmark smoke (overhead / crash parity / degradation) =="
python -m benchmarks.run --only recovery \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_recovery.json"))
g = r["gate"]
ok = (g["parity_exact"] and g["overhead_below_0.10"]
      and g["degradation_strictly_better"])
print(f"parity_exact={g['parity_exact']} "
      f"snapshot_overhead={r['overhead']['overhead_frac']:.3f} "
      f"mttr_waves={r['recovery']['mttr_redundant_waves']} "
      f"miss handled={r['degradation']['handled']['miss_rate']:.3f} vs "
      f"unhandled={r['degradation']['unhandled']['miss_rate']:.3f}")
sys.exit(0 if ok else 1)
EOF
recovery=$?

echo "== scenario-fleet gate (degradation-trained vs clean-trained) =="
python -m benchmarks.run --only scenarios \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_scenarios.json"))
g = r["gate"]
ok = g["faulted_strictly_better"] and g["clean_within_2pct"]
print(f"faulted_strictly_better={g['faulted_strictly_better']} "
      f"(deg {r['degradation_trained']['faulted_miss']:.3f} vs "
      f"clean {r['clean_trained']['faulted_miss']:.3f}) "
      f"clean_stm_ratio={r['degradation_trained']['clean_stm_ratio']:.3f} "
      f"candidate={r['degradation_trained']['candidate']}")
sys.exit(0 if ok else 1)
EOF
scenarios=$?

echo "== scan-engine parity gate (2 host devices) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=2" \
    python -m pytest -q -x tests/test_scan_engine.py
parity=$?

echo "== sharded-engine smoke (8 host devices) =="
# forced count goes last so it wins over any caller-set duplicate
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python -m benchmarks.sharded_engine --child --devices 8 \
        --lanes 16 --tasks 128 --iters 1
sharded=$?

echo "== DP-trainer parity gate (8 host devices) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python -m benchmarks.training_throughput --child --devices 8 \
        --dp-lanes 8 --tasks 96 --iters 1
dp=$?

echo "== pipeline gate (4 host devices: stage groups vs single-stage) =="
python -m benchmarks.run --only pipeline \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_pipeline.json"))
g, c = r["gate"], r["child"]
ok = (g["pipeline_beats_single_stage"] and g["parity_flat_vs_reference"]
      and g["parity_sharded_vs_flat"])
print(f"makespan_gain={c['makespan_gain']}x "
      f"({c['makespan_pipeline_s']:.2f}s pipelined vs "
      f"{c['makespan_single_stage_s']:.2f}s single-stage) "
      f"flat_vs_ref={g['parity_flat_vs_reference']} "
      f"sharded_vs_flat={g['parity_sharded_vs_flat']}")
sys.exit(0 if ok else 1)
EOF
pipeline=$?

echo "== kernel suite (interpret mode, always) =="
python -m pytest -q tests/test_kernels.py tests/test_dqn_kernel.py
kern_interp=$?

echo "== kernel suite (compiled, TPU/GPU only) =="
# same tests, same tolerances, real tiles — REPRO_KERNEL_COMPILED=1
# switches pallas_interpret_default() off on accelerator hosts.  The
# skip is EXPLICIT: a CPU-only CI run prints the reason and stays green
# on this leg rather than pretending the compiled path was exercised.
ACCEL="$(python -c 'from repro.kernels.protocol import accelerator_platform;
print(accelerator_platform() or "")')"
if [ -n "${ACCEL}" ]; then
    REPRO_KERNEL_COMPILED=1 python -m pytest -q \
        tests/test_kernels.py tests/test_dqn_kernel.py
    kern_compiled=$?
else
    echo "SKIPPED: compiled kernel leg needs a TPU/GPU accelerator;" \
         "this host is CPU-only (interpret-mode parity ran above)"
    kern_compiled=0
fi

echo "== kernel honesty gate (parity / trajectory / no-regression) =="
python -m benchmarks.run --only kernels \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_kernels.json"))
g = r["gate"]
ok = g["ok"]
t = r["td_trajectory"]
print(f"parity_ok={g['parity_ok']} "
      f"trajectory_max_param_diff={t['max_param_diff']:.2e} "
      f"trainer_no_regression={g['trainer_no_regression_ok']} "
      f"compiled_leg={g['compiled_leg'].split(':')[0]}")
sys.exit(0 if ok else 1)
EOF
kern_bench=$?

echo "== benchmark smoke (quick mode: metaheuristic throughput) =="
python -m benchmarks.run --only metaheuristic_throughput \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_metaheuristics.json"))
ok = r["fitness_parity_ok"]
print(f"fitness_parity_ok={ok} "
      f"ga_speedup={r['ga']['speedup_device_vs_loop']}x")
sys.exit(0 if ok else 1)
EOF
bench=$?

echo "== benchmark smoke (quick mode: training throughput) =="
# Gate thresholds are what the 2-core CI host sustains (fused >= 2x,
# DP >= 1x), not ISSUE-4's aspirational 10x / 1.5x — both trainers
# share the TD-update matmul compute and 4 forced devices oversubscribe
# 2 cores; see the note fields in BENCH_training.json and DESIGN.md
# "Measured reality".
python -m benchmarks.run --only training_throughput \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_training.json"))
ok = (r["eval_parity_ok"] and r["dp"]["parity_ok"]
      and r["fused_speedup_vs_loop"] >= 2.0
      and r["dp"]["speedup_4dev_vs_1dev"] >= 1.0)
print(f"fused_speedup={r['fused_speedup_vs_loop']}x "
      f"dp_speedup={r['dp']['speedup_4dev_vs_1dev']}x "
      f"eval_parity={r['eval_parity_ok']} dp_parity={r['dp']['parity_ok']}")
sys.exit(0 if ok else 1)
EOF
train_bench=$?

echo "== summary: tier1_exit=${tier1} parity_exit=${parity} sharded_exit=${sharded} dp_exit=${dp} pipeline_exit=${pipeline} bench_exit=${bench} train_bench_exit=${train_bench} serve_prop_exit=${serve_prop} serve_bench_exit=${serve_bench} serve_load_exit=${serve_load} durability_exit=${durability} recovery_exit=${recovery} scenarios_exit=${scenarios} kern_interp_exit=${kern_interp} kern_compiled_exit=${kern_compiled} kern_bench_exit=${kern_bench} =="
[ "${tier1}" -eq 0 ] && [ "${parity}" -eq 0 ] && [ "${sharded}" -eq 0 ] \
    && [ "${dp}" -eq 0 ] && [ "${pipeline}" -eq 0 ] \
    && [ "${bench}" -eq 0 ] \
    && [ "${train_bench}" -eq 0 ] && [ "${serve_prop}" -eq 0 ] \
    && [ "${serve_bench}" -eq 0 ] && [ "${serve_load}" -eq 0 ] \
    && [ "${durability}" -eq 0 ] \
    && [ "${recovery}" -eq 0 ] && [ "${scenarios}" -eq 0 ] \
    && [ "${kern_interp}" -eq 0 ] && [ "${kern_compiled}" -eq 0 ] \
    && [ "${kern_bench}" -eq 0 ]
