#!/usr/bin/env bash
# CI entry point: tier-1 suite + the scan/loop parity gate.
#
# The tier-1 suite carries known seed-era failures (kernel/sharding tests
# calibrated for TPU); those are reported but don't gate.  What gates is
# the device-resident engine: the parity + vmap tests must pass, including
# a 2-device host-platform smoke for the vmapped paths
# (XLA_FLAGS=--xla_force_host_platform_device_count=2, the standard JAX
# idiom for exercising multi-device code on CPU).
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== tier-1 suite (informational; seed has known failures) =="
python -m pytest -q
tier1=$?

echo "== scan-engine parity gate (2 host devices) =="
XLA_FLAGS="--xla_force_host_platform_device_count=2 ${XLA_FLAGS:-}" \
    python -m pytest -q -x tests/test_scan_engine.py
parity=$?

echo "== summary: tier1_exit=${tier1} parity_exit=${parity} =="
exit "${parity}"
