#!/usr/bin/env bash
# CI entry point.
#
# 1. Installs the optional dev deps (hypothesis) so tests/test_property.py
#    actually runs instead of importorskip-ing away; the install is
#    best-effort so air-gapped environments still get the rest of CI.
# 2. Runs the FULL tier-1 suite (no -x): since the PR-2 compat shim the
#    kernel, sharding and distribution suites pass on CPU jax 0.4.37, so
#    every failure gates.
# 3. Scan-engine parity gate on 2 forced host devices.
# 4. Sharded-engine smoke on 8 forced host devices: the shard_map'd
#    multi-device schedule path must match the single-device scan engine
#    (the child asserts fp32 parity before printing its result line).
# 5. Quick-mode benchmark smoke: the metaheuristic throughput module
#    (device GA/SA vs the NumPy loop + fitness parity) must run end to
#    end and report fitness parity vs the oracle.
set -uo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}

echo "== dev deps (hypothesis; best-effort) =="
python -m pip install -q -r requirements-dev.txt \
    || echo "pip install failed; property tests will be skipped"

echo "== tier-1 suite (full run, gating) =="
python -m pytest -q
tier1=$?

echo "== scan-engine parity gate (2 host devices) =="
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=2" \
    python -m pytest -q -x tests/test_scan_engine.py
parity=$?

echo "== sharded-engine smoke (8 host devices) =="
# forced count goes last so it wins over any caller-set duplicate
XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python -m benchmarks.sharded_engine --child --devices 8 \
        --lanes 16 --tasks 128 --iters 1
sharded=$?

echo "== benchmark smoke (quick mode: metaheuristic throughput) =="
python -m benchmarks.run --only metaheuristic_throughput \
    && python - <<'EOF'
import json, sys
r = json.load(open("BENCH_metaheuristics.json"))
ok = r["fitness_parity_ok"]
print(f"fitness_parity_ok={ok} "
      f"ga_speedup={r['ga']['speedup_device_vs_loop']}x")
sys.exit(0 if ok else 1)
EOF
bench=$?

echo "== summary: tier1_exit=${tier1} parity_exit=${parity} sharded_exit=${sharded} bench_exit=${bench} =="
[ "${tier1}" -eq 0 ] && [ "${parity}" -eq 0 ] && [ "${sharded}" -eq 0 ] \
    && [ "${bench}" -eq 0 ]
