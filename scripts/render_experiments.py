"""Regenerate the generated sections of EXPERIMENTS.md from
experiments/dryrun/results.jsonl (+ perf JSONs).

    PYTHONPATH=src python scripts/render_experiments.py
"""
from __future__ import annotations

import json
import os
import re
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import (load_records, markdown_table,  # noqa: E402
                                 roofline_terms)

DOC = "EXPERIMENTS.md"
BEGIN = "<!-- GENERATED:{tag} -->"
END = "<!-- /GENERATED:{tag} -->"


def inject(text: str, tag: str, content: str) -> str:
    b = BEGIN.format(tag=tag)
    e = END.format(tag=tag)
    pattern = re.compile(re.escape(b) + ".*?" + re.escape(e), re.DOTALL)
    return pattern.sub(b + "\n" + content + "\n" + e, text)


def dryrun_table(records: list) -> str:
    lines = ["| arch | shape | mesh | status | peak GiB/dev | args GiB/dev | "
             "HLO flops/dev | coll GiB/dev | collective mix | compile s |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped ({r['reason'][:48]}) | — | — | — | — | — | — |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAILED | — | — | — | — | — | — |")
            continue
        probe = r.get("probe") or {}
        flops = probe.get("flops_per_device", r.get("flops_per_device", 0))
        coll = max(0.0, probe.get("collective_operand_bytes",
                   r["collectives"]["total_operand_bytes"]))
        mix = r["collectives"]
        mixstr = " ".join(
            f"{k.split('-')[-1]}:{v['count']}"
            for k, v in mix.items()
            if isinstance(v, dict) and v["count"])
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r['peak_bytes_per_device']/2**30:.2f} "
            f"| {r['argument_bytes_per_device']/2**30:.2f} "
            f"| {flops:.3g} | {coll/2**30:.2f} | {mixstr} "
            f"| {r['compile_s']} |")
    n_ok = sum(1 for r in records if r.get("status") == "ok")
    n_skip = sum(1 for r in records if r.get("status") == "skipped")
    n_fail = sum(1 for r in records if r.get("status") == "failed")
    lines.append("")
    lines.append(f"**{n_ok} ok / {n_skip} skipped (documented) / "
                 f"{n_fail} failed** out of {len(records)} cells.")
    return "\n".join(lines)


def main():
    records = [r for r in load_records()
               if r.get("rules", "default") == "default"]
    text = open(DOC).read()
    text = inject(text, "dryrun", dryrun_table(records))
    text = inject(text, "roofline", markdown_table(
        [r for r in records if r.get("mesh") == "pod16x16"]))
    open(DOC, "w").write(text)
    print(f"rendered {len(records)} records into {DOC}")


if __name__ == "__main__":
    main()
